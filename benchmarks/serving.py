"""Continuous vs static batching serving benchmark (BENCH_serving.json).

Measures the real thing on CPU: the same jitted slot-cache steps (packed
scatter prefill + fixed-shape slot decode, DESIGN.md §12) run twice over one
heterogeneous request trace — once with continuous admission (completed
requests free slots the next tick refills) and once in drain-before-refill
mode (the classic static batch: every request waits for the batch's
slowest).  Compilation is excluded by replaying the trace on a warmup engine
that shares the compiled steps with the timed engine; sharing also makes the
compile-once guard stronger — the decode trace counter must read exactly 1
across warmup + timed runs of *both* modes.

Reported per mode: tokens/s, decode steps, slot occupancy, per-request
latency p50/p99 and time-to-first-token p50.  The headline derived metric is
``speedup_tokens_per_s`` (continuous / static), asserted > 1.3 in CI on the
heterogeneous profile.

Artifacts: ``<out>/serving.json`` + top-level ``BENCH_serving.json``.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from benchmarks.common import csv_line, timed_section


def run_mode(
    model,
    params,
    config,
    trace: list[tuple[np.ndarray, int]],
    step_cache: dict,
    repeats: int = 2,
) -> tuple[dict, dict[int, list[int]]]:
    """Warmup replay (compiles), then timed replays sharing compiled steps.

    The fastest of ``repeats`` replays is reported (standard benchmarking
    hygiene: transient host contention inflates wall time, never deflates
    it).  ``step_cache`` is shared by the caller across BOTH modes, so the
    trace counters must read 1 across every warmup and timed replay of the
    whole benchmark.
    """
    from repro.serve import ContinuousBatchingEngine

    warm = ContinuousBatchingEngine(model, params, config, step_cache=step_cache)
    for prompt, new in trace:
        warm.submit(prompt, new)
    warm.run()

    mode = "continuous" if config.continuous else "static"
    wall = float("inf")
    for rep in range(repeats):
        candidate = ContinuousBatchingEngine(
            model, params, config, step_cache=step_cache
        )
        with timed_section("bench/serve_replay", mode=mode, repeat=rep) as replay:
            cand_rids = [candidate.submit(prompt, new) for prompt, new in trace]
            cand_outputs = candidate.run()
        if replay.elapsed < wall:
            wall, engine, rids, outputs = (
                replay.elapsed, candidate, cand_rids, cand_outputs
            )

    latency = np.array([engine.requests[r].latency_s for r in rids])
    ttft = np.array(
        [
            engine.requests[r].first_token_s - engine.requests[r].submitted_s
            for r in rids
        ]
    )
    stats = engine.stats
    row = {
        "wall_s": wall,
        "tokens_per_s": stats.generated_tokens / wall,
        "generated_tokens": stats.generated_tokens,
        "decode_steps": stats.decode_steps,
        "prefill_calls": stats.prefill_calls,
        "slot_decode_occupancy": stats.slot_decode_occupancy,
        "peak_projected_tokens": stats.peak_projected_tokens,
        "latency_p50_ms": 1e3 * float(np.percentile(latency, 50)),
        "latency_p99_ms": 1e3 * float(np.percentile(latency, 99)),
        "ttft_p50_ms": 1e3 * float(np.percentile(ttft, 50)),
        "decode_traces": engine.decode_traces,
        "prefill_traces": {
            f"{r}x{c}": n for (r, c), n in sorted(engine.prefill_traces.items())
        },
    }
    return row, {rid: [int(t) for t in outputs[rid]] for rid in rids}


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--arch", default="qwen3_0_6b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--l-max", type=int, default=1024)
    ap.add_argument("--lookahead", type=int, default=32)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=96)
    ap.add_argument("--new-min", type=int, default=2)
    ap.add_argument("--new-max", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from repro.configs import get_smoke_config
    from repro.models import LM
    from repro.serve import ServeConfig, synth_request_trace

    cfg = get_smoke_config(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    trace = synth_request_trace(
        args.requests, vocab=cfg.vocab_size,
        prompt_min=args.prompt_min, prompt_max=args.prompt_max,
        new_min=args.new_min, new_max=args.new_max, seed=args.seed,
    )

    lines = []
    rows: dict[str, dict] = {}
    mode_outputs: dict[str, dict[int, list[int]]] = {}
    step_cache: dict = {}  # one cache: both modes share every compiled step
    for mode in ("continuous", "static"):
        config = ServeConfig(
            num_slots=args.slots, max_len=args.max_len, l_max=args.l_max,
            lookahead=args.lookahead, continuous=mode == "continuous",
        )
        r, mode_outputs[mode] = run_mode(model, params, config, trace, step_cache)
        rows[mode] = r
        lines.append(
            csv_line(
                f"serving/{mode}",
                1e6 * r["wall_s"],
                {
                    "tokens_per_s": f"{r['tokens_per_s']:.1f}",
                    "decode_steps": r["decode_steps"],
                    "occupancy": f"{r['slot_decode_occupancy']:.3f}",
                    "p99_ms": f"{r['latency_p99_ms']:.0f}",
                    "decode_traces": r["decode_traces"],
                },
            )
        )

    speedup = rows["continuous"]["tokens_per_s"] / rows["static"]["tokens_per_s"]
    # Continuous batching must generate the identical tokens per request —
    # the schedule changes, the math must not.  Full per-rid comparison, not
    # a digest: offsetting or reordered divergences must fail too.
    outputs_equal = mode_outputs["continuous"] == mode_outputs["static"]
    lines.append(
        csv_line(
            "serving/speedup",
            0.0,
            {"tokens_per_s_ratio": f"{speedup:.2f}", "outputs_equal": int(outputs_equal)},
        )
    )

    artifact = {
        "config": {
            "arch": cfg.name,
            "requests": args.requests,
            "slots": args.slots,
            "max_len": args.max_len,
            "l_max": args.l_max,
            "lookahead": args.lookahead,
            "prompt_range": [args.prompt_min, args.prompt_max],
            "new_tokens_range": [args.new_min, args.new_max],
            "seed": args.seed,
        },
        "modes": rows,
        "speedup_tokens_per_s": speedup,
        "outputs_equal": outputs_equal,
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "serving.json").write_text(json.dumps(artifact, indent=1))
    pathlib.Path("BENCH_serving.json").write_text(json.dumps(artifact, indent=1))
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
