"""§Roofline — aggregate the dry-run artifacts into the roofline table."""

from __future__ import annotations

import json
import pathlib

ARTIFACTS = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_rows(mesh: str | None = None) -> list[dict]:
    rows = []
    for path in sorted(ARTIFACTS.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        r = rec["roofline"]
        r["bytes_per_device_gb"] = rec["bytes_per_device"] / 1e9
        r["compile_s"] = rec.get("compile_s")
        rows.append(r)
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful | roofline_frac | GB/dev |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r['bytes_per_device_gb']:.1f} |\n"
        )
    return hdr + body


def main(argv=None) -> list[str]:
    rows = load_rows()
    out = pathlib.Path("artifacts/bench")
    out.mkdir(parents=True, exist_ok=True)
    (out / "roofline_table.md").write_text(markdown_table(rows))
    (out / "roofline.json").write_text(json.dumps(rows, indent=1))
    single = [r for r in rows if r["mesh"] == "single"]
    if not single:
        return ["roofline/summary,0.0,no_artifacts=1"]
    worst = min(single, key=lambda r: r["roofline_fraction"])
    most_coll = max(single, key=lambda r: r["collective_s"] / max(r["compute_s"] + r["memory_s"], 1e-12))
    dominants = {}
    for r in single:
        dominants[r["dominant"]] = dominants.get(r["dominant"], 0) + 1
    return [
        f"roofline/cells,0.0,n={len(rows)};single={len(single)};dominants={dominants}",
        f"roofline/worst,0.0,cell={worst['arch']}x{worst['shape']};frac={worst['roofline_fraction']:.4f}",
        f"roofline/most_collective,0.0,cell={most_coll['arch']}x{most_coll['shape']};coll_s={most_coll['collective_s']:.3e}",
    ]


if __name__ == "__main__":
    for line in main():
        print(line)
