"""Measured padded-vs-packed layout benchmark (BENCH_layout.json).

Unlike the cost-model throughput tables, everything here is *measured* on
this host: the real loader path builds real DeviceBatches through each
:class:`~repro.core.layout.BatchLayout`, and a real jitted train step (the
same ``make_train_step`` the deployment trainer uses, smoke-scale model)
executes every step on CPU.  Reported per (length profile × layout):

  * ``device_padding_fraction`` — 1 - real/occupied token slots actually
    shipped to device (the quantity the layout choice moves);
  * ``steps_per_s`` / ``tok_per_s`` — measured over the timed pass, with one
    warmup call per distinct global batch shape so XLA compiles are excluded
    (the bucket grids bound the shape census — also reported);
  * accounting totals (steps, real/device tokens, distinct shapes).

Profiles: ``longtail`` (high-CV — the acceptance profile: packed device-side
padding must sit strictly below dense) and ``uniform_narrow`` (low-CV
control).  Artifacts: ``<out>/layout.json`` + top-level ``BENCH_layout.json``.

The measured core (``measure_loader``) doubles as the ``--layout auto``
calibration probe: ``calibrate_layout`` runs a few real jitted steps of the
*launch* dataset through each layout and picks the faster one
(launch/train.py; ROADMAP "layout autotuning").
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import time

from benchmarks.common import csv_line
from repro.core import OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset, length_cv

PROFILES = ("longtail", "uniform_narrow")
HIGH_CV_PROFILE = "longtail"


def measure_loader(loader, *, max_steps: int, vocab: int = 512, arch: str = "qwen3_0_6b") -> dict:
    """Measured steps/s + device padding for one prepared loader.

    The shared probe core: realizes up to ``max_steps`` aligned steps through
    the loader's layout, drives the real jitted ``make_train_step`` on a
    smoke-scale model (one warmup per distinct global shape so XLA compiles
    are excluded), and reports the timed pass.  Used both by the
    paper-table benchmark below and by ``calibrate_layout`` (the
    ``--layout auto`` calibration pass, ROADMAP "layout autotuning").
    """
    import jax

    from repro.configs import get_smoke_config
    from repro.models import LM
    from repro.train.optimizer import OptimizerConfig, init_opt_state
    from repro.train.trainer import assemble_model_batch, make_train_step

    steps = []
    for ls in loader.epoch(0):
        steps.append(ls)
        if len(steps) >= max_steps:
            break

    cfg = dataclasses.replace(get_smoke_config(arch), vocab_size=vocab)
    model = LM(cfg)
    opt_cfg = OptimizerConfig(total_steps=100)
    train_step = jax.jit(make_train_step(model, opt_cfg))
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params, opt_cfg)}

    batches = [assemble_model_batch(ls, loader.layout) for ls in steps]
    # Warmup: one call per distinct global shape (excludes XLA compiles from
    # the timed pass; the shape census itself is a figure of merit).
    shapes = {}
    for b in batches:
        shapes.setdefault(b["tokens"].shape, b)
    for b in shapes.values():
        s2, _ = train_step(state, b)
        jax.block_until_ready(s2["params"])

    t0 = time.perf_counter()
    metrics = None
    for b in batches:
        state, metrics = train_step(state, b)
    jax.block_until_ready(state["params"])
    wall = time.perf_counter() - t0

    acc = loader.accounting
    return {
        "layout": loader.layout.name,
        "steps": len(steps),
        "real_tokens": acc.emitted_tokens,
        "device_tokens": acc.device_tokens,
        "device_padding_fraction": acc.device_padding_fraction,
        "group_padding_fraction": acc.padding_fraction,
        "distinct_shapes": len(shapes),
        "wall_s": wall,
        "steps_per_s": len(steps) / wall if wall > 0 else 0.0,
        "tok_per_s": acc.emitted_tokens / wall if wall > 0 else 0.0,
        "final_loss": float(metrics["loss"]) if metrics is not None else None,
    }


def bench_layout(
    profile: str,
    layout: str,
    *,
    data_scale: float,
    world: int,
    l_max: int,
    max_steps: int,
    vocab: int = 512,
    seed: int = 0,
) -> dict:
    ds = get_dataset(profile, scale=data_scale)
    loader = OnlineDynamicLoader(
        ds,
        world_size=world,
        config=OdbConfig(
            l_max=l_max, buffer_size=64, prefetch_factor=32, num_workers=2
        ),
        layout=layout,
        seed=seed,
        vocab_size=vocab,
    )
    row = measure_loader(loader, max_steps=max_steps, vocab=vocab)
    row.update(
        profile=profile,
        layout=layout,
        length_cv=round(length_cv(ds.lengths(seed)), 4),
    )
    return row


def calibrate_layout(
    dataset,
    world: int,
    config: OdbConfig,
    *,
    steps: int = 6,
    vocab: int = 512,
    seed: int = 0,
    bucket_spec=None,
    packed_spec=None,
) -> dict:
    """Pick dense vs packed for one run from a short measured probe.

    ``--layout auto`` (launch/train.py): instead of trusting the CLI flag,
    run a few real jitted steps of *this* dataset through each layout and
    keep the one with the higher measured steps/s (ties break toward lower
    device-side padding).  The caller's bucket grids must be passed through
    (``bucket_spec``/``packed_spec``) so the probe pads on exactly the
    boundaries the real run will — a different grid can rank the layouts
    differently.  The probe model is smoke-scale by design: the decision is
    a *relative* ranking, not an absolute throughput estimate.  Returns
    ``{"layout": choice, "results": {...}}``.
    """
    results = {}
    for layout in ("dense", "packed"):
        loader = OnlineDynamicLoader(
            dataset,
            world_size=world,
            config=config,
            bucket_spec=bucket_spec,
            packed_spec=packed_spec,
            layout=layout,
            seed=seed,
            vocab_size=vocab,
        )
        results[layout] = measure_loader(loader, max_steps=steps, vocab=vocab)
    dense, packed = results["dense"], results["packed"]
    if packed["steps_per_s"] != dense["steps_per_s"]:
        choice = max(results, key=lambda k: results[k]["steps_per_s"])
    else:
        choice = min(results, key=lambda k: results[k]["device_padding_fraction"])
    return {"layout": choice, "results": results}


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--profiles", nargs="*", default=list(PROFILES))
    ap.add_argument("--data-scale", type=float, default=0.08)
    ap.add_argument("--world", type=int, default=2)
    ap.add_argument("--l-max", type=int, default=1024)
    ap.add_argument("--max-steps", type=int, default=16)
    args = ap.parse_args(argv)

    lines = []
    profiles: dict[str, dict] = {}
    for profile in args.profiles:
        rows = {}
        for layout in ("dense", "packed"):
            r = bench_layout(
                profile,
                layout,
                data_scale=args.data_scale,
                world=args.world,
                l_max=args.l_max,
                max_steps=args.max_steps,
            )
            rows[layout] = r
            lines.append(
                csv_line(
                    f"layout/{profile}/{layout}",
                    1e6 * r["wall_s"],
                    {
                        "steps_per_s": f"{r['steps_per_s']:.2f}",
                        "device_padding": f"{r['device_padding_fraction']:.4f}",
                        "shapes": r["distinct_shapes"],
                    },
                )
            )
        rows["packed_below_dense"] = (
            rows["packed"]["device_padding_fraction"]
            < rows["dense"]["device_padding_fraction"]
        )
        profiles[profile] = rows

    artifact = {
        "config": {
            "data_scale": args.data_scale,
            "world": args.world,
            "l_max": args.l_max,
            "max_steps": args.max_steps,
            "high_cv_profile": HIGH_CV_PROFILE,
        },
        "profiles": profiles,
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "layout.json").write_text(json.dumps(artifact, indent=1))
    # Top-level perf-trajectory artifact (ISSUE 2 acceptance contract).
    pathlib.Path("BENCH_layout.json").write_text(json.dumps(artifact, indent=1))
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
