"""Kernel micro-benchmark: XLA blockwise vs Pallas flash (BENCH_kernels.json).

Everything measured on this host (DESIGN.md §11):

  * forward and forward+backward wall time of the two train-path attention
    implementations — the blockwise-XLA scan (``models/attention``) and the
    Pallas segment-aware flash kernel (``repro.kernels``, interpret mode on
    CPU, compiled on TPU) — on *real packed batches*: the high-CV
    ``longtail`` profile is run through the packed :class:`BatchLayout`, and
    the resulting segment rows drive both paths;
  * numerical parity (forward max-err on valid rows + gradient max-err) as a
    sanity rail for the timings;
  * the **live-tile census** of the flash grid under (a) causal skipping
    alone and (b) causal + segment-range block skipping — the acceptance
    quantity: packing must translate into a strictly lower live-tile
    fraction on the high-CV profile;
  * the **fetched-tile / bytes census** of the scalar-prefetch pruned grid
    (DESIGN.md §17) against the dense grid — the PR-10 acceptance rail: the
    pruned grid's kv-DMA fraction must sit strictly below the dense grid's
    on the longtail-packed profile, with bit-level fwd+grad parity between
    the two grids;
  * the **sharded dry-run cell**: the flash route (both grids) lowered and
    compiled under the production mesh via shard_map over the batch axis
    (``repro.launch.flash_dryrun`` in a subprocess with forced host
    devices);
  * the autotuned (block_q, block_kv) schedule for the bench shape
    (``repro.kernels.autotune``, persisted under ``artifacts/autotune/``).

Artifacts: ``<out>/kernels.json`` + top-level ``BENCH_kernels.json``.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from benchmarks.common import csv_line
from repro.core import OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset
from repro.kernels.flash_attention import live_tile_counts, select_block
from repro.kernels.liveness import fetched_tile_counts

HIGH_CV_PROFILE = "longtail"


def packed_rows(
    profile: str,
    *,
    data_scale: float,
    world: int,
    l_max: int,
    max_census_rows: int,
    max_steps: int,
) -> dict[int, np.ndarray]:
    """Real packed segment rows of ``profile``, grouped by row width.

    The packed layout plans one (rows, capacity) shape per aligned step, so
    widths vary across steps; collecting across steps gives both the census
    population and a narrow multi-segment set for the timed kernels."""
    loader = OnlineDynamicLoader(
        get_dataset(profile, scale=data_scale),
        world_size=world,
        config=OdbConfig(
            l_max=l_max, buffer_size=64, prefetch_factor=32, num_workers=2
        ),
        layout="packed",
        vocab_size=512,
    )
    by_width: dict[int, list[np.ndarray]] = {}
    n = 0
    for i, ls in enumerate(loader.epoch(0)):
        for batch in ls.batches:
            for r in range(batch.segments.shape[0]):
                seg = batch.segments[r]
                if seg.max() <= 0:
                    continue  # IDLE / all-padding rows carry no tiles
                by_width.setdefault(seg.shape[0], []).append(seg)
                n += 1
        if n >= max_census_rows or i + 1 >= max_steps:
            break
    return {w: np.stack(rows, axis=0) for w, rows in by_width.items()}


def aggregate_census(by_width: dict[int, np.ndarray], block: int) -> dict:
    """Live-tile census over every collected row (causal vs segment-aware)."""
    agg = {"tiles": 0, "causal_live": 0, "segment_live": 0}
    for width, rows in by_width.items():
        t = live_tile_counts(rows, width, block, block, causal=True)
        for key in agg:
            agg[key] += t[key]
    total = agg["tiles"]
    return {
        **agg,
        "block": block,
        "rows": int(sum(r.shape[0] for r in by_width.values())),
        "causal_live_fraction": agg["causal_live"] / total if total else 0.0,
        "segment_live_fraction": agg["segment_live"] / total if total else 0.0,
    }


def aggregate_fetch_census(
    by_width: dict[int, np.ndarray],
    block: int,
    *,
    heads: int,
    kv_heads: int,
    head_dim: int,
) -> dict:
    """kv-tile DMA census over every collected row, dense vs pruned grid.

    Sums the exact per-width fetch counts (``fetched_tile_counts`` walks the
    grid in pipeline order, counting kv index-map transitions) and reports
    pooled fractions — the BENCH acceptance quantity."""
    agg = {
        "grid_steps": 0,
        "live_tiles": 0,
        "dense_fetches": 0,
        "pruned_fetches": 0,
        "dense_fetched_bytes": 0,
        "pruned_fetched_bytes": 0,
    }
    for width, rows in by_width.items():
        t = fetched_tile_counts(
            rows, width, block, block,
            causal=True, heads=heads, kv_heads=kv_heads, head_dim=head_dim,
        )
        for key in agg:
            agg[key] += t[key]
    steps = agg["grid_steps"]
    return {
        **agg,
        "block": block,
        "rows": int(sum(r.shape[0] for r in by_width.values())),
        "dense_fetched_fraction": agg["dense_fetches"] / steps if steps else 0.0,
        "pruned_fetched_fraction": agg["pruned_fetches"] / steps if steps else 0.0,
    }


def sharded_flash_cell(*, seq: int, timeout_s: float = 540.0) -> dict:
    """Run the production-mesh shard_map validation in a subprocess (the
    forced host-platform device count must be set before jax init, which an
    already-initialized bench process cannot do in-process)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.setdefault("FLASH_DRYRUN_DEVICES", "256")
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable, "-m", "repro.launch.flash_dryrun",
        "--seq", str(seq), "--rows-per-shard", "1", "--json",
    ]
    try:
        proc = subprocess.run(
            cmd, capture_output=True, text=True, timeout=timeout_s, env=env
        )
    except subprocess.TimeoutExpired:
        return {"status": "error", "error": f"timeout after {timeout_s}s"}
    for line in reversed(proc.stdout.strip().splitlines()):
        try:
            out = json.loads(line)
            break
        except ValueError:
            continue
    else:
        return {
            "status": "error",
            "error": f"rc={proc.returncode}",
            "stderr": proc.stderr[-2000:],
        }
    cells = out.get("cells", {})
    ok = bool(cells) and all(c.get("status") == "ok" for c in cells.values())
    return {
        "status": "ok" if ok else "error",
        "devices": out.get("devices"),
        "cells": cells,
    }


def timing_rows(
    by_width: dict[int, np.ndarray], *, max_seq: int, max_rows: int
) -> np.ndarray:
    """Pick the timed set: the narrowest-fitting width with the most packed
    segments per row (multi-segment rows exercise the block skipping)."""
    def rank(width):
        rows = by_width[width]
        return (width <= max_seq, int(rows.max()), rows.shape[0])

    width = max(by_width, key=rank)
    return by_width[width][:max_rows]


def _time(fn, *args, repeats: int) -> float:
    import jax

    jax.block_until_ready(fn(*args))  # compile / first interpret pass
    t0 = time.perf_counter()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / repeats


def bench_kernels(
    *,
    data_scale: float,
    world: int,
    l_max: int,
    max_rows: int,
    max_seq: int,
    census_block: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    repeats: int,
) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.kernels.autotune import autotune_blocks, cached_schedule, shape_key
    from repro.kernels.ops import flash_attention
    from repro.models.attention import _block_sdpa

    by_width = packed_rows(
        HIGH_CV_PROFILE,
        data_scale=data_scale,
        world=world,
        l_max=l_max,
        max_census_rows=64,
        max_steps=16,
    )
    seg_np = timing_rows(by_width, max_seq=max_seq, max_rows=max_rows)
    b, s = seg_np.shape
    h, kv, d = heads, kv_heads, head_dim
    g = h // kv
    seg = jnp.asarray(seg_np)
    # Within-segment positions, as the packed layout ships them.
    pos_np = np.zeros_like(seg_np)
    for i in range(b):
        for sid in np.unique(seg_np[i]):
            if sid <= 0:
                continue
            idx = np.nonzero(seg_np[i] == sid)[0]
            pos_np[i, idx] = np.arange(idx.size)
    pos = jnp.asarray(pos_np)

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    valid = jnp.asarray((seg_np > 0)[:, :, None, None].astype(np.float32))
    scale = 1.0 / (d**0.5)

    block = select_block(s, 128)

    def xla_fwd(q_, k_, v_):
        qg = q_.reshape(b, s, kv, g, d)
        out = _block_sdpa(qg, k_, v_, pos, pos, seg, seg, None, True, scale)
        return out.reshape(b, s, h, d)

    def flash_fwd(q_, k_, v_):
        return flash_attention(q_, k_, v_, seg, True, block, block, "dense")

    def flash_pruned_fwd(q_, k_, v_):
        return flash_attention(q_, k_, v_, seg, True, block, block, "pruned")

    def loss_of(fwd):
        def loss(q_, k_, v_):
            return jnp.sum((fwd(q_, k_, v_).astype(jnp.float32) * valid) ** 2)
        return jax.grad(loss, argnums=(0, 1, 2))

    xla_fwd_j = jax.jit(xla_fwd)
    flash_fwd_j = jax.jit(flash_fwd)
    flash_pruned_fwd_j = jax.jit(flash_pruned_fwd)
    xla_bwd_j = jax.jit(loss_of(xla_fwd))
    flash_bwd_j = jax.jit(loss_of(flash_fwd))
    flash_pruned_bwd_j = jax.jit(loss_of(flash_pruned_fwd))

    timings = {
        "xla_fwd_s": _time(xla_fwd_j, q, k, v, repeats=repeats),
        "flash_fwd_s": _time(flash_fwd_j, q, k, v, repeats=repeats),
        "flash_pruned_fwd_s": _time(flash_pruned_fwd_j, q, k, v, repeats=repeats),
        "xla_fwdbwd_s": _time(xla_bwd_j, q, k, v, repeats=repeats),
        "flash_fwdbwd_s": _time(flash_bwd_j, q, k, v, repeats=repeats),
        "flash_pruned_fwdbwd_s": _time(flash_pruned_bwd_j, q, k, v, repeats=repeats),
    }

    # Parity rails: valid-row forward + gradient agreement vs XLA, and
    # bit-level (fwd + grads) agreement of the pruned grid vs the dense grid
    # — the dense grid is the differential-testing oracle for the DMA-level
    # pruning (identical accumulation sequence ⇒ identical bits).
    out_x = xla_fwd_j(q, k, v)
    out_f = flash_fwd_j(q, k, v)
    out_p = flash_pruned_fwd_j(q, k, v)
    fwd_err = float(jnp.max(jnp.abs((out_x - out_f) * valid)))
    g_x = xla_bwd_j(q, k, v)
    g_f = flash_bwd_j(q, k, v)
    g_p = flash_pruned_bwd_j(q, k, v)
    grad_err = max(
        float(jnp.max(jnp.abs(a - b_))) for a, b_ in zip(g_x, g_f)
    )
    pruned_fwd_err = float(jnp.max(jnp.abs(out_f - out_p)))
    pruned_grad_err = max(
        float(jnp.max(jnp.abs(a - b_))) for a, b_ in zip(g_f, g_p)
    )

    tiles = aggregate_census(by_width, census_block)
    fetch = aggregate_fetch_census(
        by_width, census_block, heads=h, kv_heads=kv, head_dim=d
    )
    sharded = sharded_flash_cell(seq=min(s, 512))
    blocks = autotune_blocks(
        b, s, h, kv, d, dtype=jnp.float32, causal=True, has_segments=True,
        repeats=1, grid="dense",
    )
    return {
        "backend": jax.default_backend(),
        "profile": HIGH_CV_PROFILE,
        "shape": {"rows": b, "seq": s, "heads": h, "kv_heads": kv, "head_dim": d},
        "block": block,
        "timings": timings,
        "parity": {
            "fwd_max_err_valid": fwd_err,
            "grad_max_err": grad_err,
            "pruned_fwd_max_err": pruned_fwd_err,
            "pruned_grad_max_err": pruned_grad_err,
            "pruned_fwd_bitexact": bool(jnp.array_equal(out_f, out_p)),
            "pruned_grad_bitexact": all(
                bool(jnp.array_equal(a, b_)) for a, b_ in zip(g_f, g_p)
            ),
        },
        "live_tiles": tiles,
        "skip_win": tiles["segment_live_fraction"] < tiles["causal_live_fraction"],
        "fetch_census": fetch,
        "prune_win": fetch["pruned_fetched_fraction"] < fetch["dense_fetched_fraction"],
        "sharded": sharded,
        "autotune": {
            "picked": list(blocks),
            "key": shape_key(
                b, s, h, kv, d, dtype=jnp.float32, causal=True,
                has_segments=True, grid="dense",
            ),
            "schedule": {kk: list(vv) for kk, vv in cached_schedule().items()},
        },
    }


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--data-scale", type=float, default=0.04)
    ap.add_argument("--world", type=int, default=2)
    ap.add_argument("--l-max", type=int, default=512)
    ap.add_argument("--max-rows", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--census-block", type=int, default=64)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--head-dim", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=2)
    args = ap.parse_args(argv)

    r = bench_kernels(
        data_scale=args.data_scale,
        world=args.world,
        l_max=args.l_max,
        max_rows=args.max_rows,
        max_seq=args.max_seq,
        census_block=args.census_block,
        heads=args.heads,
        kv_heads=args.kv_heads,
        head_dim=args.head_dim,
        repeats=args.repeats,
    )
    lines = [
        csv_line(
            "kernels/xla/fwd", 1e6 * r["timings"]["xla_fwd_s"],
            {"seq": r["shape"]["seq"], "rows": r["shape"]["rows"]},
        ),
        csv_line(
            "kernels/flash/fwd", 1e6 * r["timings"]["flash_fwd_s"],
            {"block": r["block"]},
        ),
        csv_line(
            "kernels/xla/fwdbwd", 1e6 * r["timings"]["xla_fwdbwd_s"], {}
        ),
        csv_line(
            "kernels/flash/fwdbwd", 1e6 * r["timings"]["flash_fwdbwd_s"],
            {"grad_err": f"{r['parity']['grad_max_err']:.2e}"},
        ),
        csv_line(
            "kernels/flash_pruned/fwd", 1e6 * r["timings"]["flash_pruned_fwd_s"],
            {"bitexact": int(r["parity"]["pruned_fwd_bitexact"])},
        ),
        csv_line(
            "kernels/flash_pruned/fwdbwd",
            1e6 * r["timings"]["flash_pruned_fwdbwd_s"],
            {"bitexact": int(r["parity"]["pruned_grad_bitexact"])},
        ),
        csv_line(
            "kernels/live_tiles", 0.0,
            {
                "causal": f"{r['live_tiles']['causal_live_fraction']:.4f}",
                "segment": f"{r['live_tiles']['segment_live_fraction']:.4f}",
                "skip_win": int(r["skip_win"]),
            },
        ),
        csv_line(
            "kernels/fetched_tiles", 0.0,
            {
                "dense": f"{r['fetch_census']['dense_fetched_fraction']:.4f}",
                "pruned": f"{r['fetch_census']['pruned_fetched_fraction']:.4f}",
                "prune_win": int(r["prune_win"]),
                "sharded": r["sharded"]["status"],
            },
        ),
    ]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "kernels.json").write_text(json.dumps(r, indent=1))
    # Top-level perf-trajectory artifact (ISSUE 3 acceptance contract).
    pathlib.Path("BENCH_kernels.json").write_text(json.dumps(r, indent=1))
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
