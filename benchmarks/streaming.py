"""Streaming-executor benchmark — eager vs incremental vs prefetch data paths.

Measures the *real* data-side pipeline on CPU (no cost model): pipeline
realization, DGAP rounds, grouping/alignment, bucket padding.  A configurable
synthetic train-step cost (``--step-cost`` seconds of sleep, standing in for
the jitted step the prefetcher overlaps with) exposes the overlap win.

Reported per path:

  * ``ttfs``      — time to first step (s): the eager path pays the whole
    epoch's realization + protocol rounds before step 1; streaming pays O(D);
  * ``steady``    — steady-state steps/s over the remaining steps;
  * ``wall``      — end-to-end epoch wall time (s);
  * ``hit_rate``  — prefetch hits / requests (prefetch path only);
  * ``peak_window`` — peak realized-lengths resident in the admission window.

Artifacts: ``<out>/streaming.json`` plus the top-level ``BENCH_streaming.json``
perf-trajectory artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

from benchmarks.common import csv_line, timed_section
from repro import obs
from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset


def _consume(step_iter, step_cost: float) -> dict:
    steps = 0
    samples = 0
    it = iter(step_iter)
    with timed_section("bench/stream_epoch") as epoch:
        with timed_section("bench/stream_ttfs") as ttfs:
            loader_step = next(it, None)
        while loader_step is not None:
            steps += 1
            samples += loader_step.metadata.emitted_samples
            if step_cost > 0:
                time.sleep(step_cost)  # stand-in for the jitted train step
            loader_step = next(it, None)
    ttfs_s = ttfs.elapsed if steps else 0.0
    steady = 0.0
    if steps > 1 and epoch.elapsed > ttfs_s:
        steady = (steps - 1) / (epoch.elapsed - ttfs_s)
    return {
        "steps": steps,
        "samples": samples,
        "ttfs_s": ttfs_s,
        "wall_s": epoch.elapsed,
        "steady_steps_per_s": steady,
    }


def bench_paths(
    dataset: str,
    *,
    data_scale: float,
    world: int,
    l_max: int,
    buffer_size: int,
    lookahead: int | None,
    step_cost: float,
    seed: int = 0,
) -> dict:
    def make_loader() -> OnlineDynamicLoader:
        ds = get_dataset(dataset, scale=data_scale)
        return OnlineDynamicLoader(
            ds,
            world_size=world,
            config=OdbConfig(
                l_max=l_max, buffer_size=buffer_size,
                prefetch_factor=32, num_workers=2,
            ),
            bucket_spec=BucketSpec(min_len=64, max_len=16384, max_count=1024),
            seed=seed,
        )

    rows: dict[str, dict] = {}

    loader = make_loader()
    rows["eager"] = _consume(loader.epoch(0), step_cost)

    loader = make_loader()
    rows["stream"] = _consume(
        loader.streaming_epoch(0, lookahead=lookahead), step_cost
    )
    rows["stream"]["peak_window"] = loader.last_executor.window_stats().peak_resident

    loader = make_loader()
    rows["stream_prefetch"] = _consume(
        loader.streaming_epoch(0, lookahead=lookahead, prefetch=True),
        step_cost,
    )
    rows["stream_prefetch"]["peak_window"] = (
        loader.last_executor.window_stats().peak_resident
    )
    if loader.last_prefetch_stats is not None:
        rows["stream_prefetch"].update(
            hit_rate=loader.last_prefetch_stats.hit_rate,
            consumer_wait_s=loader.last_prefetch_stats.wait_s,
        )
    return rows


def bench_telemetry_overhead(
    make_loader, *, step_cost: float, lookahead: int | None, repeats: int = 2
) -> dict:
    """A/B the stream path with telemetry fully off vs fully on.

    The acceptance bound (ISSUE 6): enabled telemetry costs < 3% steady
    steps/s.  Best-of-``repeats`` per arm (host contention inflates wall
    time, never deflates it); registry/tracer enablement is restored
    afterwards so the surrounding benchmark keeps its ambient state.
    """
    registry = obs.default_registry()
    tracer = obs.default_tracer()
    was_reg, was_trace = registry.enabled, tracer.enabled

    def arm(enabled: bool) -> dict:
        registry.enabled = enabled
        tracer.enabled = enabled
        best: dict | None = None
        for _ in range(repeats):
            loader = make_loader()
            r = _consume(loader.streaming_epoch(0, lookahead=lookahead), step_cost)
            if best is None or r["steady_steps_per_s"] > best["steady_steps_per_s"]:
                best = r
        return best

    try:
        off = arm(False)
        on = arm(True)
    finally:
        registry.enabled, tracer.enabled = was_reg, was_trace
    overhead_pct = 0.0
    if off["steady_steps_per_s"] > 0:
        overhead_pct = 100.0 * (
            1.0 - on["steady_steps_per_s"] / off["steady_steps_per_s"]
        )
    return {
        "disabled_steps_per_s": off["steady_steps_per_s"],
        "enabled_steps_per_s": on["steady_steps_per_s"],
        "telemetry_overhead_pct": overhead_pct,
    }


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--dataset", default="ultrachat")
    ap.add_argument("--data-scale", type=float, default=0.004)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--l-max", type=int, default=4096)
    ap.add_argument("--buffer", type=int, default=64)
    ap.add_argument("--lookahead", type=int, default=256)
    ap.add_argument("--step-cost", type=float, default=0.002)
    args = ap.parse_args(argv)  # None -> sys.argv (standalone CLI)

    rows = bench_paths(
        args.dataset,
        data_scale=args.data_scale,
        world=args.world,
        l_max=args.l_max,
        buffer_size=args.buffer,
        lookahead=args.lookahead,
        step_cost=args.step_cost,
    )

    def make_loader() -> OnlineDynamicLoader:
        ds = get_dataset(args.dataset, scale=args.data_scale)
        return OnlineDynamicLoader(
            ds,
            world_size=args.world,
            config=OdbConfig(
                l_max=args.l_max, buffer_size=args.buffer,
                prefetch_factor=32, num_workers=2,
            ),
            bucket_spec=BucketSpec(min_len=64, max_len=16384, max_count=1024),
            seed=0,
        )

    overhead = bench_telemetry_overhead(
        make_loader, step_cost=args.step_cost, lookahead=args.lookahead
    )

    lines = []
    for path, r in rows.items():
        derived = {
            "steps": r["steps"],
            "steady_steps_per_s": f"{r['steady_steps_per_s']:.2f}",
            "ttfs_ms": f"{1e3 * r['ttfs_s']:.1f}",
        }
        if "hit_rate" in r:
            derived["hit_rate"] = f"{r['hit_rate']:.3f}"
        if "peak_window" in r:
            derived["peak_window"] = r["peak_window"]
        lines.append(csv_line(f"streaming/{path}", 1e6 * r["wall_s"], derived))

    lines.append(
        csv_line(
            "streaming/telemetry_overhead",
            0.0,
            {
                "overhead_pct": f"{overhead['telemetry_overhead_pct']:.2f}",
                "enabled_steps_per_s": f"{overhead['enabled_steps_per_s']:.2f}",
                "disabled_steps_per_s": f"{overhead['disabled_steps_per_s']:.2f}",
            },
        )
    )

    artifact = {
        "config": {
            "dataset": args.dataset,
            "data_scale": args.data_scale,
            "world": args.world,
            "l_max": args.l_max,
            "buffer": args.buffer,
            "lookahead": args.lookahead,
            "step_cost_s": args.step_cost,
        },
        "paths": rows,
        "telemetry": overhead,
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "streaming.json").write_text(json.dumps(artifact, indent=1))
    # Top-level perf-trajectory artifact (ISSUE 1 acceptance contract).
    pathlib.Path("BENCH_streaming.json").write_text(json.dumps(artifact, indent=1))
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
