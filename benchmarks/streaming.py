"""Streaming-executor benchmark — eager vs incremental vs prefetch data paths.

Measures the *real* data-side pipeline on CPU (no cost model): pipeline
realization, DGAP rounds, grouping/alignment, bucket padding.  A configurable
synthetic train-step cost (``--step-cost`` seconds of sleep, standing in for
the jitted step the prefetcher overlaps with) exposes the overlap win.

Reported per path:

  * ``ttfs``      — time to first step (s): the eager path pays the whole
    epoch's realization + protocol rounds before step 1; streaming pays O(D);
  * ``steady``    — steady-state steps/s over the remaining steps;
  * ``wall``      — end-to-end epoch wall time (s);
  * ``hit_rate``  — prefetch hits / requests (prefetch path only);
  * ``peak_window`` — peak realized-lengths resident in the admission window.

Artifacts: ``<out>/streaming.json`` plus the top-level ``BENCH_streaming.json``
perf-trajectory artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import time

from benchmarks.common import csv_line, timed_section
from repro import obs
from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset


def _consume(step_iter, step_cost: float, digest=None) -> dict:
    steps = 0
    samples = 0
    it = iter(step_iter)
    with timed_section("bench/stream_epoch") as epoch:
        with timed_section("bench/stream_ttfs") as ttfs:
            loader_step = next(it, None)
        while loader_step is not None:
            steps += 1
            samples += loader_step.metadata.emitted_samples
            if digest is not None:  # bit-exactness rail across data paths
                for b in loader_step.batches:
                    digest.update(b.tokens.tobytes())
                    digest.update(b.loss_mask.tobytes())
                    digest.update(b.lengths.tobytes())
            if step_cost > 0:
                time.sleep(step_cost)  # stand-in for the jitted train step
            loader_step = next(it, None)
    ttfs_s = ttfs.elapsed if steps else 0.0
    steady = 0.0
    if steps > 1 and epoch.elapsed > ttfs_s:
        steady = (steps - 1) / (epoch.elapsed - ttfs_s)
    return {
        "steps": steps,
        "samples": samples,
        "ttfs_s": ttfs_s,
        "wall_s": epoch.elapsed,
        "steady_steps_per_s": steady,
    }


def bench_paths(
    dataset: str,
    *,
    data_scale: float,
    world: int,
    l_max: int,
    buffer_size: int,
    lookahead: int | None,
    step_cost: float,
    seed: int = 0,
) -> dict:
    def make_loader() -> OnlineDynamicLoader:
        ds = get_dataset(dataset, scale=data_scale)
        return OnlineDynamicLoader(
            ds,
            world_size=world,
            config=OdbConfig(
                l_max=l_max, buffer_size=buffer_size,
                prefetch_factor=32, num_workers=2,
            ),
            bucket_spec=BucketSpec(min_len=64, max_len=16384, max_count=1024),
            seed=seed,
        )

    rows: dict[str, dict] = {}

    loader = make_loader()
    rows["eager"] = _consume(loader.epoch(0), step_cost)

    loader = make_loader()
    rows["stream"] = _consume(
        loader.streaming_epoch(0, lookahead=lookahead), step_cost
    )
    rows["stream"]["peak_window"] = loader.last_executor.window_stats().peak_resident

    loader = make_loader()
    rows["stream_prefetch"] = _consume(
        loader.streaming_epoch(0, lookahead=lookahead, prefetch=True),
        step_cost,
    )
    rows["stream_prefetch"]["peak_window"] = (
        loader.last_executor.window_stats().peak_resident
    )
    if loader.last_prefetch_stats is not None:
        rows["stream_prefetch"].update(
            hit_rate=loader.last_prefetch_stats.hit_rate,
            consumer_wait_s=loader.last_prefetch_stats.wait_s,
        )
    return rows


def bench_workers(
    dataset: str = "longtail",
    *,
    data_scale: float = 16.0,
    world: int = 8,
    l_max: int = 16384,
    buffer_size: int = 256,
    lookahead: int | None = None,
    step_cost: float = 0.0,
    worker_counts: tuple[int, ...] = (0, 2, 4),
    seed: int = 0,
    repeats: int = 2,
) -> dict:
    """Multi-process realization workers (DESIGN.md §14): ``nw`` sweep.

    Profile: the longtail length mix under the *packed* layout at a large
    per-rank token budget (8 ranks x 16k tokens) — per-step realization
    there is a pure-Python packing plan (row-capacity grid search x
    first-fit) plus padding/token synthesis over ~2 MB of arrays, i.e.
    exactly the GIL-bound work the in-process prefetch thread cannot
    overlap with the protocol (measured here: build dominates protocol
    ~3:1 per step).  ``nw=0`` is the in-process prefetch path; ``nw>0``
    ships that work to spawned workers staging through the shared-memory
    ring.

    Reported per arm: steady steps/s, wall, producer-stall time (consumer
    ``wait_s``), and a sha256 digest over every delivered array —
    ``workers_equal`` asserts the worker stream is bit-identical to the
    in-process one (acceptance rail, checked in CI).

    The *speedup* rail (nw=2 >= 1.15x nw=0) is hardware-conditional: worker
    processes parallelize CPU-bound realization, so the win only exists when
    the host has cores for parent + workers to run concurrently.  The artifact
    records ``cpu_count`` and a ``speedup_rail`` verdict; CI enforces the
    threshold only when ``cpu_count >= 3`` and otherwise keeps the measurement
    informational (a single-core host serializes everything and can only show
    IPC overhead — the bit-exactness rail still holds there).
    """
    import hashlib

    def make_loader() -> OnlineDynamicLoader:
        ds = get_dataset(dataset, scale=data_scale)
        return OnlineDynamicLoader(
            ds,
            world_size=world,
            config=OdbConfig(
                l_max=l_max, buffer_size=buffer_size,
                prefetch_factor=32, num_workers=2,
            ),
            bucket_spec=BucketSpec(min_len=64, max_len=16384, max_count=1024),
            layout="packed",
            seed=seed,
        )

    sweep: dict[str, dict] = {}
    digests: dict[int, str] = {}
    for nw in worker_counts:
        best: dict | None = None
        for _ in range(max(1, repeats)):
            loader = make_loader()
            digest = hashlib.sha256()
            row = _consume(
                loader.streaming_epoch(
                    0, lookahead=lookahead, prefetch=True, num_workers=nw
                ),
                step_cost,
                digest=digest,
            )
            digests[nw] = digest.hexdigest()
            if loader.last_prefetch_stats is not None:
                row["producer_stall_s"] = loader.last_prefetch_stats.wait_s
                row["hit_rate"] = loader.last_prefetch_stats.hit_rate
            if loader.last_worker_stats is not None:
                row["worker_stats"] = loader.last_worker_stats.as_dict()
            if best is None or row["steady_steps_per_s"] > best["steady_steps_per_s"]:
                best = row
        sweep[str(nw)] = best

    base = sweep.get("0", {}).get("steady_steps_per_s", 0.0)
    for nw in worker_counts:
        row = sweep[str(nw)]
        row["digest_sha256"] = digests[nw]
        row["speedup_vs_nw0"] = (
            row["steady_steps_per_s"] / base if base > 0 else 0.0
        )

    try:
        cpu_count = len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux fallback
        cpu_count = os.cpu_count() or 1
    measured = sweep.get("2", {}).get("speedup_vs_nw0")
    enforce = cpu_count >= 3 and measured is not None
    if os.environ.get("ODB_BENCH_REQUIRE_MULTICORE") and not enforce:
        # The CI worker-speedup lane pins a >=3-core runner class exactly so
        # this rail is always enforced; a quiet downgrade to informational
        # there means the runner pin regressed, which must fail loudly.
        raise RuntimeError(
            f"ODB_BENCH_REQUIRE_MULTICORE set but the speedup rail cannot be "
            f"enforced (cpu_count={cpu_count}, nw2 measured={measured})"
        )
    speedup_rail = {
        "threshold": 1.15,
        "measured_nw2": measured,
        "cpu_count": cpu_count,
        "enforced": enforce,
        "passed": (measured >= 1.15) if enforce else None,
        "reason": (
            "enforced: host has cores for parent + 2 workers"
            if enforce
            else f"informational: {cpu_count} core(s) cannot run parent and "
            "workers concurrently, so CPU-bound realization cannot speed up"
        ),
    }
    return {
        "profile": {
            "dataset": dataset, "data_scale": data_scale, "world": world,
            "l_max": l_max, "buffer": buffer_size, "lookahead": lookahead,
            "step_cost_s": step_cost, "layout": "packed",
            "cpu_count": cpu_count,
        },
        "sweep": sweep,
        "workers_equal": len(set(digests.values())) == 1,
        "speedup_rail": speedup_rail,
    }


def bench_telemetry_overhead(
    make_loader, *, step_cost: float, lookahead: int | None, repeats: int = 2
) -> dict:
    """A/B the stream path with telemetry fully off vs fully on.

    The acceptance bound (ISSUE 6): enabled telemetry costs < 3% steady
    steps/s.  Best-of-``repeats`` per arm (host contention inflates wall
    time, never deflates it); registry/tracer enablement is restored
    afterwards so the surrounding benchmark keeps its ambient state.
    """
    registry = obs.default_registry()
    tracer = obs.default_tracer()
    was_reg, was_trace = registry.enabled, tracer.enabled

    def arm(enabled: bool) -> dict:
        registry.enabled = enabled
        tracer.enabled = enabled
        best: dict | None = None
        for _ in range(repeats):
            loader = make_loader()
            r = _consume(loader.streaming_epoch(0, lookahead=lookahead), step_cost)
            if best is None or r["steady_steps_per_s"] > best["steady_steps_per_s"]:
                best = r
        return best

    try:
        off = arm(False)
        on = arm(True)
    finally:
        registry.enabled, tracer.enabled = was_reg, was_trace
    overhead_pct = 0.0
    if off["steady_steps_per_s"] > 0:
        overhead_pct = 100.0 * (
            1.0 - on["steady_steps_per_s"] / off["steady_steps_per_s"]
        )
    return {
        "disabled_steps_per_s": off["steady_steps_per_s"],
        "enabled_steps_per_s": on["steady_steps_per_s"],
        "telemetry_overhead_pct": overhead_pct,
    }


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--dataset", default="ultrachat")
    ap.add_argument("--data-scale", type=float, default=0.004)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--l-max", type=int, default=4096)
    ap.add_argument("--buffer", type=int, default=64)
    ap.add_argument("--lookahead", type=int, default=256)
    ap.add_argument("--step-cost", type=float, default=0.002)
    ap.add_argument(
        "--worker-scale", type=float, default=16.0,
        help="longtail dataset scale for the worker (nw) sweep (large enough "
             "that per-step realization dominates the protocol ~3:1)",
    )
    ap.add_argument(
        "--worker-step-cost", type=float, default=0.0,
        help="synthetic train-step cost for the worker sweep (0: the sweep "
             "isolates data-side throughput, where the GIL bites)",
    )
    args = ap.parse_args(argv)  # None -> sys.argv (standalone CLI)

    rows = bench_paths(
        args.dataset,
        data_scale=args.data_scale,
        world=args.world,
        l_max=args.l_max,
        buffer_size=args.buffer,
        lookahead=args.lookahead,
        step_cost=args.step_cost,
    )

    def make_loader() -> OnlineDynamicLoader:
        ds = get_dataset(args.dataset, scale=args.data_scale)
        return OnlineDynamicLoader(
            ds,
            world_size=args.world,
            config=OdbConfig(
                l_max=args.l_max, buffer_size=args.buffer,
                prefetch_factor=32, num_workers=2,
            ),
            bucket_spec=BucketSpec(min_len=64, max_len=16384, max_count=1024),
            seed=0,
        )

    overhead = bench_telemetry_overhead(
        make_loader, step_cost=args.step_cost, lookahead=args.lookahead
    )

    # The worker sweep runs its own heavy-realization profile (8 ranks x 16k
    # token budget) rather than inheriting the lighter CLI profile above —
    # the nw comparison is only meaningful where per-step build dominates.
    workers = bench_workers(
        data_scale=args.worker_scale,
        step_cost=args.worker_step_cost,
    )

    lines = []
    for path, r in rows.items():
        derived = {
            "steps": r["steps"],
            "steady_steps_per_s": f"{r['steady_steps_per_s']:.2f}",
            "ttfs_ms": f"{1e3 * r['ttfs_s']:.1f}",
        }
        if "hit_rate" in r:
            derived["hit_rate"] = f"{r['hit_rate']:.3f}"
        if "peak_window" in r:
            derived["peak_window"] = r["peak_window"]
        lines.append(csv_line(f"streaming/{path}", 1e6 * r["wall_s"], derived))

    for nw, r in workers["sweep"].items():
        derived = {
            "steps": r["steps"],
            "steady_steps_per_s": f"{r['steady_steps_per_s']:.2f}",
            "speedup_vs_nw0": f"{r['speedup_vs_nw0']:.3f}",
        }
        if "producer_stall_s" in r:
            derived["producer_stall_s"] = f"{r['producer_stall_s']:.3f}"
        lines.append(
            csv_line(f"streaming/workers_nw{nw}", 1e6 * r["wall_s"], derived)
        )

    lines.append(
        csv_line(
            "streaming/telemetry_overhead",
            0.0,
            {
                "overhead_pct": f"{overhead['telemetry_overhead_pct']:.2f}",
                "enabled_steps_per_s": f"{overhead['enabled_steps_per_s']:.2f}",
                "disabled_steps_per_s": f"{overhead['disabled_steps_per_s']:.2f}",
            },
        )
    )

    artifact = {
        "config": {
            "dataset": args.dataset,
            "data_scale": args.data_scale,
            "world": args.world,
            "l_max": args.l_max,
            "buffer": args.buffer,
            "lookahead": args.lookahead,
            "step_cost_s": args.step_cost,
        },
        "paths": rows,
        "telemetry": overhead,
        "workers": workers,
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "streaming.json").write_text(json.dumps(artifact, indent=1))
    # Top-level perf-trajectory artifact (ISSUE 1 acceptance contract).
    pathlib.Path("BENCH_streaming.json").write_text(json.dumps(artifact, indent=1))
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
