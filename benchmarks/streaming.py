"""Streaming-executor benchmark — eager vs incremental vs prefetch data paths.

Measures the *real* data-side pipeline on CPU (no cost model): pipeline
realization, DGAP rounds, grouping/alignment, bucket padding.  A configurable
synthetic train-step cost (``--step-cost`` seconds of sleep, standing in for
the jitted step the prefetcher overlaps with) exposes the overlap win.

Reported per path:

  * ``ttfs``      — time to first step (s): the eager path pays the whole
    epoch's realization + protocol rounds before step 1; streaming pays O(D);
  * ``steady``    — steady-state steps/s over the remaining steps;
  * ``wall``      — end-to-end epoch wall time (s);
  * ``hit_rate``  — prefetch hits / requests (prefetch path only);
  * ``peak_window`` — peak realized-lengths resident in the admission window.

Artifacts: ``<out>/streaming.json`` plus the top-level ``BENCH_streaming.json``
perf-trajectory artifact.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

from benchmarks.common import csv_line
from repro.core import BucketSpec, OdbConfig
from repro.data import OnlineDynamicLoader, get_dataset


def _consume(step_iter, step_cost: float) -> dict:
    t0 = time.perf_counter()
    t_first = None
    steps = 0
    samples = 0
    for loader_step in step_iter:
        if t_first is None:
            t_first = time.perf_counter() - t0
        steps += 1
        samples += loader_step.metadata.emitted_samples
        if step_cost > 0:
            time.sleep(step_cost)  # stand-in for the jitted train step
    wall = time.perf_counter() - t0
    steady = 0.0
    if steps > 1 and wall > (t_first or 0.0):
        steady = (steps - 1) / (wall - (t_first or 0.0))
    return {
        "steps": steps,
        "samples": samples,
        "ttfs_s": t_first or 0.0,
        "wall_s": wall,
        "steady_steps_per_s": steady,
    }


def bench_paths(
    dataset: str,
    *,
    data_scale: float,
    world: int,
    l_max: int,
    buffer_size: int,
    lookahead: int | None,
    step_cost: float,
    seed: int = 0,
) -> dict:
    def make_loader() -> OnlineDynamicLoader:
        ds = get_dataset(dataset, scale=data_scale)
        return OnlineDynamicLoader(
            ds,
            world_size=world,
            config=OdbConfig(
                l_max=l_max, buffer_size=buffer_size,
                prefetch_factor=32, num_workers=2,
            ),
            bucket_spec=BucketSpec(min_len=64, max_len=16384, max_count=1024),
            seed=seed,
        )

    rows: dict[str, dict] = {}

    loader = make_loader()
    rows["eager"] = _consume(loader.epoch(0), step_cost)

    loader = make_loader()
    rows["stream"] = _consume(
        loader.streaming_epoch(0, lookahead=lookahead), step_cost
    )
    rows["stream"]["peak_window"] = loader.last_executor.window_stats().peak_resident

    loader = make_loader()
    rows["stream_prefetch"] = _consume(
        loader.streaming_epoch(0, lookahead=lookahead, prefetch=True),
        step_cost,
    )
    rows["stream_prefetch"]["peak_window"] = (
        loader.last_executor.window_stats().peak_resident
    )
    if loader.last_prefetch_stats is not None:
        rows["stream_prefetch"].update(
            hit_rate=loader.last_prefetch_stats.hit_rate,
            consumer_wait_s=loader.last_prefetch_stats.wait_s,
        )
    return rows


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--dataset", default="ultrachat")
    ap.add_argument("--data-scale", type=float, default=0.004)
    ap.add_argument("--world", type=int, default=4)
    ap.add_argument("--l-max", type=int, default=4096)
    ap.add_argument("--buffer", type=int, default=64)
    ap.add_argument("--lookahead", type=int, default=256)
    ap.add_argument("--step-cost", type=float, default=0.002)
    args = ap.parse_args(argv)  # None -> sys.argv (standalone CLI)

    rows = bench_paths(
        args.dataset,
        data_scale=args.data_scale,
        world=args.world,
        l_max=args.l_max,
        buffer_size=args.buffer,
        lookahead=args.lookahead,
        step_cost=args.step_cost,
    )

    lines = []
    for path, r in rows.items():
        derived = {
            "steps": r["steps"],
            "steady_steps_per_s": f"{r['steady_steps_per_s']:.2f}",
            "ttfs_ms": f"{1e3 * r['ttfs_s']:.1f}",
        }
        if "hit_rate" in r:
            derived["hit_rate"] = f"{r['hit_rate']:.3f}"
        if "peak_window" in r:
            derived["peak_window"] = r["peak_window"]
        lines.append(csv_line(f"streaming/{path}", 1e6 * r["wall_s"], derived))

    artifact = {
        "config": {
            "dataset": args.dataset,
            "data_scale": args.data_scale,
            "world": args.world,
            "l_max": args.l_max,
            "buffer": args.buffer,
            "lookahead": args.lookahead,
            "step_cost_s": args.step_cost,
        },
        "paths": rows,
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    (outdir / "streaming.json").write_text(json.dumps(artifact, indent=1))
    # Top-level perf-trajectory artifact (ISSUE 1 acceptance contract).
    pathlib.Path("BENCH_streaming.json").write_text(json.dumps(artifact, indent=1))
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in main():
        print(line)
