"""Table 1 / 13 / 14 — emitted-sample throughput + decomposition.

All methods produce their *real* batch schedules (real grouping, alignment,
padding, update geometry); the H20 cost model (benchmarks/common.py) turns
schedules into wall time.  Speedups normalize to the Standard row, as in the
paper.
"""

from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import (
    MODEL_2B,
    MODEL_8B,
    PREP_RATE,
    ScheduleReport,
    evaluate_schedule,
)
from repro.core import OdbConfig
from repro.data import (
    LengthCache,
    bmt_schedule,
    get_dataset,
    gmt_schedule,
    hfg_schedule,
    odb_schedule,
    packing_schedule,
    sorted_schedule,
    standard_schedule,
)

WORLD = 8

# Selected configurations (paper App. I per-config tuples; bs from §3.1 sweeps)
SELECTED = {
    ("ultrachat", "8b"): dict(std_bs=8, sorted_bs=16, lmax=12288, budget=16384, hfg_bs=16),
    ("ultrachat", "2b"): dict(std_bs=8, sorted_bs=16, lmax=16384, budget=16384, hfg_bs=8),
    ("llava", "8b"): dict(std_bs=8, sorted_bs=16, lmax=12288, budget=16384, hfg_bs=16),
    ("llava", "2b"): dict(std_bs=4, sorted_bs=16, lmax=8192, budget=8192, hfg_bs=8),
    ("sharegpt4o", "8b"): dict(std_bs=1, sorted_bs=1, lmax=12288, budget=12288, hfg_bs=1),
    ("sharegpt4o", "2b"): dict(std_bs=1, sorted_bs=2, lmax=4096, budget=12288, hfg_bs=1),
    ("mmmix", "2b"): dict(std_bs=1, sorted_bs=2, lmax=12288, budget=12288, hfg_bs=2),
}


def run_dataset(dataset: str, scale_tag: str, *, data_scale: float = 0.05, seed: int = 0):
    model = MODEL_8B if scale_tag == "8b" else MODEL_2B
    sel = SELECTED[(dataset, scale_tag)]
    ds = get_dataset(dataset, scale=data_scale)
    lengths = ds.lengths(seed=seed)
    cache = LengthCache.build(ds, seed=seed)
    prep = PREP_RATE.get(dataset, PREP_RATE["default"])

    rows: list[ScheduleReport] = []

    def ev(method, steps, **kw):
        rows.append(
            evaluate_schedule(method, steps, model, prep_rate=prep, **kw)
        )

    ev("standard", standard_schedule(lengths, WORLD, sel["std_bs"], seed=seed))
    ev("sorted", sorted_schedule(lengths, WORLD, sel["sorted_bs"], seed=seed))
    if dataset == "ultrachat":  # packing is text-only in the paper's stack
        ev("packing", packing_schedule(lengths, WORLD, sel["budget"], seed=seed), packed=True)
    ev("gmt_oracle", gmt_schedule(cache, WORLD, sel["budget"]))
    ev("bmt_oracle", bmt_schedule(cache, WORLD, sel["budget"], seed=seed))
    ev("hfg_oracle", hfg_schedule(cache, WORLD, sel["hfg_bs"], seed=seed))
    cfg = OdbConfig(
        l_max=sel["lmax"], buffer_size=1024, prefetch_factor=256, num_workers=4
    )
    steps, audit = odb_schedule(lengths, WORLD, cfg, seed=seed)
    ev("odb", steps, depth=cfg.depth)

    std = rows[0].sam_per_s
    out = []
    for r in rows:
        d = r.row()
        d.update(dataset=dataset, model=scale_tag, speedup=r.sam_per_s / std)
        out.append(d)
    out[-1]["eta_identity"] = audit.eta_identity
    out[-1]["eta_quota"] = audit.eta_quota
    return out


def main(argv=None) -> list[str]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05)
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args(argv)
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    lines = []
    all_rows = []
    for dataset, tag in SELECTED:
        rows = run_dataset(dataset, tag, data_scale=args.scale)
        all_rows.extend(rows)
        std = next(r for r in rows if r["method"] == "standard")
        odb = next(r for r in rows if r["method"] == "odb")
        lines.append(
            f"throughput/{dataset}_{tag},"
            f"{1e6 * odb['wall_s'] / max(odb['upd_per_epoch'],1):.1f},"
            f"odb_speedup={odb['speedup']:.2f};odb_pad%={odb['padding_pct']:.2f};"
            f"std_pad%={std['padding_pct']:.2f};sam_upd={odb['sam_per_upd']:.1f}"
        )
    (outdir / "throughput.json").write_text(json.dumps(all_rows, indent=1))
    return lines


if __name__ == "__main__":
    for line in main():
        print(line)
